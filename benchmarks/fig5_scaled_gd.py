"""Paper Fig. 5: scaled vs non-scaled Armijo GD on the symmetric curve
sum x_i^2/2^5 and the asymmetric curve sum x_i^2/2^i (sigma=0.1, a=1.5sigma).

Claim reproduced: comparable on the symmetric curve; scaled wins by orders
of magnitude on the asymmetric one (the gap grows with T)."""
import time

import jax
import jax.numpy as jnp

from repro.core import ArmijoConfig, armijo_search, next_alpha_max
from .common import emit


def run_gd(f, a_scale, T=2000, sigma=0.1):
    cfg = ArmijoConfig(sigma=sigma, a_scale=a_scale)

    @jax.jit
    def step(w, amax):
        g = jax.grad(f)(w)
        res = armijo_search(f, w, g, amax, cfg)
        return w - a_scale * res.alpha * g, next_alpha_max(res.alpha, cfg)

    w = jnp.ones((10,))
    amax = jnp.float32(cfg.alpha0)
    t0 = time.time()
    for _ in range(T):
        w, amax = step(w, amax)
    us = (time.time() - t0) / T * 1e6
    return float(f(w)), us


def main() -> dict:
    sym_scales = jnp.full((10,), 2.0 ** -5)
    asym_scales = 2.0 ** -jnp.arange(1, 11)

    def f_sym(w):
        return jnp.sum(sym_scales * w ** 2)

    def f_asym(w):
        return jnp.sum(asym_scales * w ** 2)

    out = {}
    for curve, f in (("sym", f_sym), ("asym", f_asym)):
        for label, a in (("scaled_a1.5s", 0.15), ("nonscaled", 1.0)):
            loss, us = run_gd(f, a)
            emit(f"fig5_{curve}_{label}", us, f"final_loss={loss:.3e}")
            out[f"{curve}_{label}"] = loss
    ratio = out["asym_nonscaled"] / max(out["asym_scaled_a1.5s"], 1e-30)
    emit("fig5_asym_speedup", 0.0, f"nonscaled/scaled_loss_ratio={ratio:.1f}x")
    assert out["asym_scaled_a1.5s"] < out["asym_nonscaled"], \
        "paper Fig5 claim failed"
    return out


if __name__ == "__main__":
    main()
