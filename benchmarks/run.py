# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable (d)).

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig5,...]

Modules (paper artifact -> module):
    Fig 5  (scaled vs non-scaled GD)         fig5_scaled_gd
    Fig 4  (scaling necessity, compressed)   fig4_scaling_necessity
    Figs 1-3 (NN training vs non-adaptive)   fig1_nn_training
    Table I (validation accuracy)            table1_validation
    SIV-B  (Armijo overhead)                 armijo_overhead
    comm saving (core claim, quantified)     collective_bytes
    kernels (hot-path micro-bench)           kernel_bench
"""
import argparse
import sys
import time
import traceback

from . import (armijo_overhead, collective_bytes, fig1_nn_training,
               fig4_scaling_necessity, fig5_scaled_gd, kernel_bench,
               table1_validation)

MODULES = {
    "fig5": fig5_scaled_gd,
    "fig4": fig4_scaling_necessity,
    "fig1": fig1_nn_training,
    "table1": table1_validation,
    "armijo": armijo_overhead,
    "collective": collective_bytes,
    "kernels": kernel_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args, _ = ap.parse_known_args()
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            MODULES[name].main()
            print(f"bench_{name}_wall,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:
            failures.append(name)
            traceback.print_exc()
            print(f"bench_{name}_wall,{(time.time()-t0)*1e6:.0f},"
                  f"FAILED:{type(e).__name__}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
