"""Shared benchmark helpers: timed optimizer loops + CSV emission.

Every bench prints ``name,us_per_call,derived`` rows (the repo contract);
``derived`` carries the figure-specific quantity (final loss, accuracy,
ratio, ...).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def run_optimizer(opt, loss_of_batch, params, batches, jit=True):
    """Run ``opt`` over ``batches``; returns (losses, us_per_step, state)."""
    state = opt.init(params)

    def step(p, s, b):
        return opt.step(lambda pp: loss_of_batch(pp, b), p, s)

    if jit:
        step = jax.jit(step)
    losses = []
    t0 = time.time()
    for b in batches:
        params, state, aux = step(params, state, b)
        losses.append(float(aux.loss))
        if not np.isfinite(losses[-1]) or losses[-1] > 1e15:
            break
    us = (time.time() - t0) / max(len(losses), 1) * 1e6
    return losses, us, state


def trailing_mean(xs, k=10):
    xs = [x for x in xs if np.isfinite(x)]
    if not xs:
        return float("inf")
    return float(np.mean(xs[-k:]))
