"""Communication-saving table (the paper's raison d'etre, quantified for
our production models): per-step per-worker gradient wire bytes, dense
all-reduce vs top_k-with-feedback at gamma in {1%, 4%, 10%}.

Analytic from the actual parameter trees (k*(4B val + 4B idx) per layer,
<1000-param layers dense), plus the measured wire bytes from the dry-run
records when available."""
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.core import Compressor, tree_wire_bytes
from repro.models import build_model
from .common import emit


def main() -> dict:
    out = {}
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        model = build_model(cfg)
        params_like = jax.eval_shape(model.init,
                                     jax.ShapeDtypeStruct((2,), jnp.uint32))
        dense = sum(x.size * 4 for x in jax.tree.leaves(params_like))
        row = {"dense": dense}
        for gamma in (0.01, 0.04, 0.10):
            comp = Compressor(gamma=gamma)
            wire = tree_wire_bytes(params_like, comp)
            row[f"g{gamma:g}"] = wire
            emit(f"collective_bytes_{arch}_g{gamma:g}", 0.0,
                 f"wire={wire:.3e};dense={dense:.3e};"
                 f"saving={dense / wire:.1f}x")
        out[arch] = row
    return out


if __name__ == "__main__":
    main()
