"""Paper Fig. 4: scaled vs non-scaled CSGD-ASSS on interpolated linear
regression — the paper's exact setup: n=10000, d=1024, top_k with k/d=1%,
features N(0,1) (4a) and N(0,10) (4b).

Claim reproduced: without scaling the loss increases exponentially; with
scaling (a=3sigma) it converges."""
import jax.numpy as jnp
import numpy as np

from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.data.synthetic import interpolated_regression, regression_batch
from .common import emit, run_optimizer, trailing_mean

N, D, GAMMA, BATCH, STEPS = 10000, 1024, 0.01, 64, 300


def bench_one(feature_std: float, use_scaling: bool, seed=0):
    A, b, _ = interpolated_regression(N, D, feature_std=feature_std,
                                      seed=seed)

    def loss_of_batch(w, batch):
        Ab, bb = batch
        return jnp.mean((Ab @ w - bb) ** 2)

    cfg = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=GAMMA, min_compress_size=1),
        use_scaling=use_scaling)
    batches = [regression_batch(A, b, BATCH, t) for t in range(STEPS)]
    losses, us, _ = run_optimizer(csgd_asss(cfg), loss_of_batch,
                                  jnp.zeros(D), batches)
    return losses, us


def main() -> dict:
    out = {}
    for fig, std in (("4a_N01", 1.0), ("4b_N010", np.sqrt(10.0))):
        for label, scaling in (("scaled_3s", True), ("nonscaled", False)):
            losses, us = bench_one(std, scaling)
            final = trailing_mean(losses, 5)
            diverged = (not np.isfinite(losses[-1])) or losses[-1] > 1e6
            emit(f"fig{fig}_{label}", us,
                 f"final_loss={final:.3e};diverged={diverged};"
                 f"steps_run={len(losses)}")
            out[f"{fig}_{label}"] = (final, diverged)
    assert not out["4a_N01_scaled_3s"][1], "scaled must converge (4a)"
    assert out["4a_N01_nonscaled"][1], "nonscaled must diverge (4a)"
    assert out["4b_N010_nonscaled"][1], "nonscaled must diverge (4b)"
    return out


if __name__ == "__main__":
    main()
