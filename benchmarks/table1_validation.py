"""Paper Table I (CPU-scale): validation accuracy of CSGD-ASSS vs tuned
non-adaptive compressed SGD on held-out data.

Claim reproduced: CSGD-ASSS validation accuracy is competitive with the
best hand-tuned non-adaptive step size (within a small margin) without any
tuning."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import MLP_CONFIG, init_net, mlp_net_logits, net_loss
from repro.core import (ArmijoConfig, Compressor, CSGDConfig, NonAdaptiveCSGD,
                        csgd_asss)
from repro.data.synthetic import class_batch, teacher_classification
from .common import emit, run_optimizer

STEPS, BATCH = 200, 64


def accuracy(params, x, y):
    logits = mlp_net_logits(params, x)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def main() -> dict:
    key = jax.random.PRNGKey(0)
    cfg = MLP_CONFIG
    x, y = teacher_classification(4096, n_classes=cfg.n_classes, seed=2,
                                  image=False)
    xtr, ytr, xva, yva = x[:3072], y[:3072], x[3072:], y[3072:]
    batches = [class_batch(xtr, ytr, BATCH, t) for t in range(STEPS)]

    rows = {}
    for gamma in (0.015, 0.10):          # paper's 1.5% and 10%
        comp = Compressor(gamma=gamma)
        opts = {
            "3sigma": csgd_asss(CSGDConfig(
                armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                compressor=comp)),
            "0.1": NonAdaptiveCSGD(eta=0.1, compressor=comp),
            "0.05": NonAdaptiveCSGD(eta=0.05, compressor=comp),
            "0.01": NonAdaptiveCSGD(eta=0.01, compressor=comp),
        }
        accs = {}
        for name, opt in opts.items():
            params = init_net(cfg, key)
            state = opt.init(params)

            @jax.jit
            def step(p, s, b, _opt=opt):
                return _opt.step(lambda pp: net_loss(cfg, pp, b), p, s)
            for b in batches:
                params, state, _ = step(params, state, b)
            accs[name] = accuracy(params, xva, yva)
        best_na = max(v for k, v in accs.items() if k != "3sigma")
        emit(f"table1_mlp_cp{gamma*100:g}", 0.0,
             ";".join(f"{k}={v:.3f}" for k, v in accs.items())
             + f";competitive={accs['3sigma'] >= best_na - 0.05}")
        rows[gamma] = accs
    return rows


if __name__ == "__main__":
    main()
