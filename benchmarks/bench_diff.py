"""BENCH_kernels.json trajectory diffing (ROADMAP item; ISSUE 4 satellite).

Compares a fresh ``--smoke`` kernel-bench run against the committed
baseline per (op, backend, shape) and fails the build when an op's
median_ms regressed by more than ``--factor`` (default 1.5x) — the perf
trajectory is no longer write-only.

Two rule sets:

* **cross-run** — every (op, backend, shape) present in BOTH files:
  ``fresh <= factor * baseline`` on the burst-resistant ``min_ms``
  statistic.  Ops that appear only on one side are reported but never
  fail (new ops join the baseline when it is refreshed; this also keeps
  the diff robust to shape-set changes).  This rule is BLOCKING in CI
  at the default 1.5x now that the ``bench-baseline`` refresh job has
  held steady on the tier-1 runner class; a tighter 1.2x early-warning
  variant runs as a separate ``continue-on-error`` step.  ``--cross-run
  warn`` (kept for local runs against a committed-elsewhere baseline)
  demotes violations to warnings — per-op window minima of ~10 ms
  interpret-mode ops can swing 2-4x across heterogeneous machines.
* **within-run fusion claims** — the ``ef2pass_tel_ratio_*`` records
  (telemetry-fused EF pass-1 vs the plain fused op, DESIGN.md §10) carry
  a PAIRED wall-time ratio measured by ``kernel_bench.paired_ratio`` in
  the fresh run itself (dimensionless, stored in the ``median_ms``
  field); it must sit under ``--tel-factor`` (default 1.10x).  Pairing
  adjacent calls cancels machine drift, so this certifies the
  "telemetry costs no extra HBM sweep" claim without cross-machine (or
  even cross-second) noise.
* **within-run transport claim** — the ``bucketed_vs_perleaf_step_*``
  records (bucketed vs per-leaf compressed exchange on a leaf-heavy
  synthetic pytree, DESIGN.md §11) carry the same paired ratio and are
  hard-gated at ``--bucket-factor`` (default 1.0x): the bucketed
  transport must never be SLOWER than the per-leaf schedule it replaced
  (measured ~0.87x on the gated workload, so the 1.0x gate has real
  headroom while still being a genuine "not slower" claim).  The
  ``bucketed_vs_overlap_step_*`` records (DESIGN.md §14) make the same
  claim for the chunked-ring overlap transport in its bit-exact
  ``delay=0`` mode — the ring schedule must not be slower than the flat
  gather it replaces — hard-gated at ``--overlap-factor`` (default
  1.0x).  The stale ``delay=1`` mode is timed as an ungated
  ``exchange_step`` record: its single-device cost is the EF-current
  roundtrip, while the overlap win it exists for needs a real network.
  The ``guarded_vs_unguarded_step_*`` records (DESIGN.md §16) gate the
  hostile-wire claim at ``--guard-factor`` (default 1.05x): the
  always-on decode verdicts + quarantine must stay ~free on a clean
  wire vs the same exchange traced with ``guards_disabled()``.
  The ``gossip_vs_bucketed_step_*`` records (DESIGN.md §12) ride the
  same pairing but are informational only — the serverless path's fixed
  overhead is a design trade, not a regression.  Likewise the
  ``dense_vs_downlink_step_*`` records (DESIGN.md §15): the compressed
  downlink's replicated server recompression is the agreed price of
  halving the accounted per-link bytes, so its paired factor is printed
  for the trajectory but never gated.

Usage (the CI invocation)::

    python -m benchmarks.kernel_bench --smoke --out BENCH_fresh.json
    python -m benchmarks.bench_diff BENCH_kernels.json BENCH_fresh.json

Cross-run absolute timings only compare cleanly on comparable machines;
CI runners are assumed homogeneous enough for the 1.5x guard.  Tune with
``--factor`` / the BENCH_DIFF_FACTOR env var when they are not.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

TEL_RATIO_PREFIX = "ef2pass_tel_ratio_"
BUCKET_RATIO_PREFIX = "bucketed_vs_perleaf_step_"
OVERLAP_RATIO_PREFIX = "bucketed_vs_overlap_step_"
GOSSIP_RATIO_PREFIX = "gossip_vs_bucketed_step_"
DOWNLINK_RATIO_PREFIX = "dense_vs_downlink_step_"
GUARD_RATIO_PREFIX = "guarded_vs_unguarded_step_"
FED_STEP_PREFIX = "fed_cohort_step_"


def _key(rec: dict) -> tuple:
    shape = rec["shape"]
    shape = tuple(shape) if isinstance(shape, list) else (shape,)
    return (rec["op"], rec["backend"], shape)


def _load(path: str) -> dict[tuple, float]:
    """(op, backend, shape) -> milliseconds.  Prefers ``min_ms`` (see
    kernel_bench.timeit: the window minimum survives load bursts that
    inflate a whole median window) and falls back to ``median_ms`` for
    pre-ISSUE-4 baselines."""
    with open(path) as fh:
        data = json.load(fh)
    return {_key(r): float(r.get("min_ms", r["median_ms"]))
            for r in data["records"]}


def diff(baseline: dict[tuple, float], fresh: dict[tuple, float],
         factor: float, tel_factor: float, min_ms: float = 0.25,
         cross_run_fail: bool = True,
         bucket_factor: float = 1.0,
         overlap_factor: float = 1.0,
         guard_factor: float = 1.05) -> list[str]:
    """Returns the list of failure messages (empty = pass).

    ``min_ms``: noise floor for the cross-run rule — keys where both
    sides sit under it are reported but cannot fail (sub-millisecond
    CPU timings flap well past 1.5x run-to-run; a real regression in a
    hot op crosses the floor).  ``cross_run_fail=False``: cross-run
    violations are printed but not returned as failures.
    """
    failures = []

    def is_ratio(k):
        return k[0].startswith((TEL_RATIO_PREFIX, BUCKET_RATIO_PREFIX,
                                OVERLAP_RATIO_PREFIX, GOSSIP_RATIO_PREFIX,
                                DOWNLINK_RATIO_PREFIX, GUARD_RATIO_PREFIX))

    shared = sorted(k for k in set(baseline) & set(fresh) if not is_ratio(k))
    for k in shared:
        base, cur = baseline[k], fresh[k]
        ratio = cur / max(base, 1e-9)
        tiny = max(base, cur) < min_ms
        flag = ("noise-floor" if tiny and ratio > factor else
                "REGRESSION" if ratio > factor else "ok")
        print(f"  {k[0]:28s} {k[1]:16s} {str(k[2]):18s} "
              f"{base:10.4f} -> {cur:10.4f} ms  ({ratio:5.2f}x) {flag}")
        if ratio > factor and not tiny and cross_run_fail:
            failures.append(
                f"{k}: {base:.4f} -> {cur:.4f} ms ({ratio:.2f}x > "
                f"{factor}x)")
    for k in sorted(set(fresh) - set(baseline)):
        print(f"  {k[0]:28s} {k[1]:16s} {str(k[2]):18s} "
              f"{'new':>10s} -> {fresh[k]:10.4f} ms")
    for k in sorted(set(baseline) - set(fresh)):
        print(f"  {k[0]:28s} {k[1]:16s} {str(k[2]):18s} "
              f"{baseline[k]:10.4f} -> {'gone':>10s}")

    # within-run: the paired telemetry/plain ratio records of the fresh run
    n_ratio = 0
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if not op.startswith(TEL_RATIO_PREFIX):
            continue
        n_ratio += 1
        flag = "FUSION BROKEN" if ratio > tel_factor else "ok"
        print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
              f"(limit {tel_factor}x) {flag}")
        if ratio > tel_factor:
            failures.append(
                f"{op}{shape}: telemetry pass costs {ratio:.3f}x the plain "
                f"fused op (> {tel_factor}x) — the fused-reduction claim "
                f"(DESIGN.md §10) no longer holds")
    if n_ratio == 0:
        failures.append(
            f"no {TEL_RATIO_PREFIX}* records in the fresh run — the "
            f"fused-telemetry claim went unmeasured")

    # within-run: bucketed-vs-perleaf transport ratio (DESIGN.md §11)
    n_bucket = 0
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if not op.startswith(BUCKET_RATIO_PREFIX):
            continue
        n_bucket += 1
        flag = "BUCKETING SLOWER" if ratio > bucket_factor else "ok"
        print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
              f"(limit {bucket_factor}x) {flag}")
        if ratio > bucket_factor:
            failures.append(
                f"{op}{shape}: bucketed transport costs {ratio:.3f}x the "
                f"per-leaf schedule (> {bucket_factor}x) — the coalesced "
                f"exchange (DESIGN.md §11) regressed below the path it "
                f"replaced")
    if n_bucket == 0:
        failures.append(
            f"no {BUCKET_RATIO_PREFIX}* records in the fresh run — the "
            f"bucketed-transport claim went unmeasured")

    # within-run: overlap(delay=0)-vs-bucketed transport ratio (DESIGN.md
    # §14) — the chunked-ring schedule must not be slower than the flat
    # bucketed gather it replaces
    n_overlap = 0
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if not op.startswith(OVERLAP_RATIO_PREFIX):
            continue
        n_overlap += 1
        flag = "RING SLOWER" if ratio > overlap_factor else "ok"
        print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
              f"(limit {overlap_factor}x) {flag}")
        if ratio > overlap_factor:
            failures.append(
                f"{op}{shape}: overlap transport costs {ratio:.3f}x the "
                f"bucketed exchange (> {overlap_factor}x) — the chunked-"
                f"ring schedule (DESIGN.md §14) is slower than the flat "
                f"gather it replaced")
    if n_overlap == 0:
        failures.append(
            f"no {OVERLAP_RATIO_PREFIX}* records in the fresh run — the "
            f"overlap-transport claim went unmeasured")

    # within-run: guarded-vs-unguarded decode ratio (DESIGN.md §16) — the
    # always-on verdict/quarantine layer must stay ~free on a clean wire
    n_guard = 0
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if not op.startswith(GUARD_RATIO_PREFIX):
            continue
        n_guard += 1
        flag = "GUARDS NOT FREE" if ratio > guard_factor else "ok"
        print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
              f"(limit {guard_factor}x) {flag}")
        if ratio > guard_factor:
            failures.append(
                f"{op}{shape}: guarded decode costs {ratio:.3f}x the "
                f"unguarded exchange (> {guard_factor}x) — the hostile-"
                f"wire defenses (DESIGN.md §16) are no longer ~free on "
                f"the clean-wire fast path")
    if n_guard == 0:
        failures.append(
            f"no {GUARD_RATIO_PREFIX}* records in the fresh run — the "
            f"guards-are-free claim went unmeasured")

    # informational: gossip-vs-bucketed paired overhead (DESIGN.md §12) —
    # printed for the trajectory, never gated (cross-transport thresholds
    # are a design choice, not a regression signal)
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if op.startswith(GOSSIP_RATIO_PREFIX):
            print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
                  f"(informational)")

    # informational: compressed-downlink-vs-dense-return paired factor
    # (DESIGN.md §15) — the replicated server recompression prices the
    # accounted byte halving; a design trade, never gated
    for (op, backend, shape), ratio in sorted(fresh.items()):
        if op.startswith(DOWNLINK_RATIO_PREFIX):
            print(f"  {op:36s} {str(shape):18s} paired ratio {ratio:5.3f}x "
                  f"(informational)")

    # informational: federated cohort simulation throughput (DESIGN.md
    # §13) — clients/sec derived from the burst-resistant window minimum;
    # a capacity trajectory, not a gate (it still rides the cross-run
    # rule above once the record lands in the committed baseline)
    for (op, backend, shape), ms in sorted(fresh.items()):
        if not op.startswith(FED_STEP_PREFIX):
            continue
        n_clients = shape[0] if isinstance(shape[0], int) else 0
        rate = n_clients / (ms / 1e3) if ms > 0 else float("inf")
        print(f"  {op:36s} {str(shape):18s} {ms:10.4f} ms  "
              f"({rate:,.0f} clients/s, informational)")
    if not shared:
        print("  (no shared (op, backend, shape) keys — cross-run diff "
              "was vacuous; refresh the committed baseline)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_kernels.json")
    ap.add_argument("fresh", help="freshly produced bench JSON")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_DIFF_FACTOR", 1.5)),
                    help="cross-run median_ms regression threshold")
    ap.add_argument("--tel-factor", type=float, default=1.10,
                    help="within-run telemetry-vs-plain EF threshold")
    ap.add_argument("--bucket-factor", type=float, default=1.0,
                    help="within-run bucketed-vs-perleaf transport "
                         "threshold (bucketed must not be slower)")
    ap.add_argument("--overlap-factor", type=float, default=1.0,
                    help="within-run overlap(delay=0)-vs-bucketed "
                         "transport threshold (the ring schedule must "
                         "not be slower)")
    ap.add_argument("--guard-factor", type=float, default=1.05,
                    help="within-run guarded-vs-unguarded decode "
                         "threshold (the §16 verdict/quarantine layer "
                         "must stay ~free on a clean wire)")
    ap.add_argument("--min-ms", type=float, default=0.25,
                    help="cross-run noise floor (see diff())")
    ap.add_argument("--cross-run", choices=["fail", "warn"], default="fail",
                    help="whether >factor cross-run regressions fail the "
                         "run (default) or only warn — see module "
                         "docstring for when warn is the right call")
    args = ap.parse_args()
    print(f"bench diff: {args.baseline} -> {args.fresh} "
          f"(factor {args.factor}x, tel {args.tel_factor}x, "
          f"bucket {args.bucket_factor}x, overlap {args.overlap_factor}x, "
          f"guard {args.guard_factor}x, "
          f"floor {args.min_ms} ms, cross-run={args.cross_run})")
    failures = diff(_load(args.baseline), _load(args.fresh),
                    args.factor, args.tel_factor, min_ms=args.min_ms,
                    cross_run_fail=args.cross_run == "fail",
                    bucket_factor=args.bucket_factor,
                    overlap_factor=args.overlap_factor,
                    guard_factor=args.guard_factor)
    if failures:
        print("\nFAIL:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("bench diff: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
