"""Paper §IV-B computational-complexity note: with omega=1.2, rho=0.8 the
Armijo search costs on average < 1 extra forward pass per step (~2
stopping-condition evaluations)."""
import jax

from repro.configs import get_smoke_config
from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.data.synthetic import TokenPipeline
from repro.models import build_model
from .common import emit, run_optimizer


def main() -> dict:
    key = jax.random.PRNGKey(0)
    cfg = get_smoke_config("yi-34b")
    model = build_model(cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=16)
    opt = csgd_asss(CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3, omega=1.2, rho=0.8),
        compressor=Compressor(gamma=0.01)))
    params = model.init(key)
    batches = [pipe.batch(t) for t in range(60)]
    losses, us, state = run_optimizer(
        opt, lambda p, b: model.loss(p, b)[0], params, batches)
    evals = float(state.n_evals_ema)
    extra_fwd = evals - 1.0
    emit("armijo_overhead_lm", us,
         f"avg_condition_evals={evals:.2f};extra_fwd_per_step={extra_fwd:.2f};"
         f"paper_claim_lt1={extra_fwd < 1.0}")
    return {"evals": evals}


if __name__ == "__main__":
    main()
