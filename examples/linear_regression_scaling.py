"""Paper Fig. 4 reproduction: why scaling is NECESSARY for compressed SGD
with Armijo search (not a proof technicality).

Interpolated linear regression, the paper's exact setup: n=10000, d=1024,
top_k at 1%, batch 64.  Run both variants and watch the unscaled one
diverge exponentially while the scaled one (a = 3*sigma) converges.

    PYTHONPATH=src python examples/linear_regression_scaling.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.data.synthetic import interpolated_regression, regression_batch


def run(use_scaling: bool, steps=200):
    A, b, _ = interpolated_regression(10000, 1024, seed=0)
    cfg = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=0.01, min_compress_size=1),
        use_scaling=use_scaling)
    opt = csgd_asss(cfg)
    w = jnp.zeros(1024)
    st = opt.init(w)

    @jax.jit
    def step(w, st, Ab, bb):
        return opt.step(lambda ww: jnp.mean((Ab @ ww - bb) ** 2), w, st)

    label = "scaled(a=3s)" if use_scaling else "non-scaled  "
    for t in range(steps):
        Ab, bb = regression_batch(A, b, 64, t)
        w, st, aux = step(w, st, Ab, bb)
        if t % 25 == 0 or t == steps - 1:
            print(f"  {label} step {t:4d}  loss={float(aux.loss):.4e}")
        if not np.isfinite(float(aux.loss)) or float(aux.loss) > 1e12:
            print(f"  {label} DIVERGED at step {t}")
            return float("inf")
    return float(aux.loss)


def main():
    print("== with scaling (paper CSGD-ASSS) ==")
    ls = run(True)
    print("== without scaling (naive Armijo + top_k) ==")
    lu = run(False)
    print(f"\nfinal: scaled={ls:.3e}  unscaled={lu:.3e}")
    # initial loss ~ d = 1024; scaled must be converging (well below the
    # start), unscaled must have blown up by orders of magnitude.
    assert ls < 300.0 and (lu > 1e6 or not np.isfinite(lu)), (ls, lu)
    print("paper Fig. 4 claim reproduced: scaling is necessary.")


if __name__ == "__main__":
    main()
