"""Serving example: batched prefill + autoregressive decode with KV caches.

Greedy-decodes a batch of requests from a (randomly initialized) model of
any assigned architecture family — demonstrates the prefill/decode_step
API the production decode shapes (decode_32k, long_500k) lower.

    PYTHONPATH=src python examples/serve_decode.py [arch]
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-7b"
    assert arch in ARCH_NAMES, f"pick one of {ARCH_NAMES}"
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B, CTX, GEN = 4, 48, 16
    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (B, CTX), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["src_embed"] = jax.random.normal(key, (B, 32, cfg.d_model))

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, capacity=CTX + GEN))(params, batch)
    print(f"[{arch}] prefill {B}x{CTX} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(GEN - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(CTX + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    dt = (time.time() - t0) / (GEN - 1)
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {GEN} tokens/request @ {dt*1e3:.1f} ms/step")
    for i in range(B):
        print(f"  req{i}: {list(map(int, gen[i]))}")


if __name__ == "__main__":
    main()
