"""DCSGD-ASSS (paper Algorithm 3) on a simulated 8-chip mesh.

Each data-parallel worker line-searches on ITS OWN batch, compresses its
gradient with error feedback, and only the sparse (values, indices) pairs
cross the wire — watch the wire-bytes column vs the dense baseline.  Each
step also logs the worker-mean compression telemetry (DESIGN.md §10): the
EF backlog ratio ``||m'||/||g||`` and the decode/gradient cosine — the
signal the ``ef-coupled`` gamma controller closes the loop on.

    PYTHONPATH=src python examples/distributed_training.py
(the script re-execs itself with XLA_FLAGS for 8 host devices)

The same machinery from the training CLI (repro/launch/train.py)::

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \\
        --mesh 4x2 --gamma 0.005 --max-gamma 0.05 \\
        --gamma-schedule ef-coupled --ef-target 0.15 --ef-band 0.08

``--gamma-schedule ef-coupled`` adapts the per-round compression level
from the EF backlog (grow when backlog leaves the hysteresis band above
``--ef-target + --ef-band`` — over-compressed; shrink below ``--ef-target
- --ef-band`` while the decode cosine is healthy); ``--max-gamma`` sizes
the static ragged wire budget the controller moves inside.  Unlike
``armijo-coupled`` it senses over-compression directly, so a too-small
``--gamma`` start recovers instead of stalling at ``--gamma-min``
(tests/test_golden_convergence.py pins that pairing).

The exchange itself is **bucketed** (DESIGN.md §11, the default): every
compressed leaf's packed payload rides ONE flat ``all_gather`` per step
(down from one collective per leaf), the pack/unpack and fused-EF
kernels launch once per bucket instead of once per leaf, and every dense
small leaf folds into a single ``pmean`` — same bytes on the wire, same
updates bit for bit.  ``--transport perleaf`` restores the per-leaf
reference schedule for A/B timing or debugging::

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \\
        --mesh 4x2 --compress-method block_topk --transport perleaf

**Serverless** (DESIGN.md §12): ``--transport gossip`` drops the server
role entirely — the SAME packed payload moves by ``degree`` neighbor
``ppermute``\\ s on a fixed mixing graph, each worker consensus-averages
itself + neighbors with an AdaGossip-style adaptive consensus step, and
per-worker models converge through the topology's spectral gap::

    python examples/distributed_training.py --transport gossip \\
        --topology ring --consensus-lr 1.0

Byte accounting is PER LINK so transports stay comparable: a gossip
worker's uplink carries ``degree x`` the per-link payload (ring: 2x),
where the gather-based transports pay ``(W-1) x`` — the printed
``wire_bytes/link`` is the same per-payload figure for all of them.

**Overlapped** (DESIGN.md §14): ``--transport overlap`` streams the SAME
packed buffer around a chunked ``ppermute`` ring instead of one flat
``all_gather`` and, at ``--overlap-delay 1`` (the default), ships the
PREVIOUS step's payload so the collective runs concurrently with this
step's compute — the applied mean is one step stale (watch the
``staleness`` column flip 0 -> 1 after the warm-up step) while EF and
telemetry stay current.  ``--overlap-delay 0`` is the bit-exact bucketed
drop-in; ``--overlap-chunks`` sets the ring section count::

    python examples/distributed_training.py --transport overlap \\
        --overlap-chunks 4 --overlap-delay 1

**Compressed downlink** (DESIGN.md §15): ``--downlink compressed``
closes the return direction — the replicated aggregate the bucketed
gather decodes is re-compressed through the SAME wire format with a
server-side error-feedback memory before workers apply it, so BOTH
directions ship packed payload rows.  No extra collective: the server is
physically simulated (every worker runs the identical compress/EF), only
the accounting changes.  Watch the per-direction columns — ``up`` stays
the uplink payload, ``down`` drops from dense f32 bytes to the payload
budget at ``--downlink-gamma``::

    python examples/distributed_training.py --downlink compressed \\
        --downlink-gamma 0.05

**Federated cohort simulation** (DESIGN.md §13): ``--n-clients N`` vmaps
``N / W`` simulated clients onto each dp worker — per-client EF memory,
per-client gamma, non-IID Dirichlet-tilted shards, partial participation
— while the whole cohort still moves on ONE all_gather + ONE psum per
round.  The demo runs the same non-IID cohort twice to show WHY
support-weighted aggregation is the default: ``support`` divides each
coordinate by the clients that actually sent it, ``mean`` averages in
the zeros absent coordinates leave behind (watch the loss gap and the
``participants`` column)::

    python examples/distributed_training.py --n-clients 32 \\
        --clients-per-round 24

The training CLI exposes the full surface::

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \\
        --mesh 4x2 --n-clients 64 --clients-per-round 48 \\
        --dirichlet-alpha 0.3 --aggregation support --straggler-rate 0.1

**Hostile-wire robustness** (DESIGN.md §16): ``--fault-demo`` runs the
same compressed exchange with a seeded fault campaign corrupting worker
0's gathered payload rows (bit flips, poisoned ragged counts, NaN/Inf
scale fields) for a 5-step burst.  The defensive decode layer verdicts
every row, quarantines the invalid ones (zeroed, with the mean's
denominator adjusted), and freezes the victim's EF residual for the
round — watch the ``quar`` column light up during the burst while the
loss keeps descending.  The step-level circuit breaker backs the
verdicts up: any non-finite round skips the parameter write bit-exactly
(``skips`` column) and ``--max-consecutive-skips`` consecutive skips
raise ``DivergenceError`` naming the last good step.  The training CLI
carries the full surface::

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \\
        --mesh 4x2 --fault-nonfinite 0.5 --fault-worker 0 \\
        --fault-start-step 10 --fault-steps 5 --fault-seed 7

``--no-quarantine`` disables the verdict layer (corrupt rows flow into
the mean — the breaker alone keeps parameters finite) and
``--max-consecutive-skips 0`` disables the breaker; with both off a
burst is pinned divergent by tests/test_golden_convergence.py.
"""
import argparse
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.faults import FaultConfig
from repro.comm.gossip import GossipConfig
from repro.comm.overlap import OverlapConfig
from repro.comm.topology import TOPOLOGIES, build_topology
from repro.comm.transport import transport_names
from repro.configs import get_smoke_config
from repro.configs.base import (FederatedConfig, OptimizerConfig,
                                RunConfig, ShapeConfig)
from repro.fed.sampling import participation_mask
from repro.core import ArmijoConfig, Compressor, GammaControllerConfig
from repro.data.synthetic import TokenPipeline
from repro.launch.train_step import (build_train_step, init_opt_state,
                                     opt_state_shardings)
from repro.models import build_model
from repro.sharding import param_shardings


def run(kind: str, steps=15, gamma=0.02, transport="bucketed",
        gossip=GossipConfig(), overlap=OverlapConfig(),
        downlink="dense", downlink_gamma=0.0, faults=FaultConfig()):
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("yi-34b")
    model = build_model(cfg)
    run_cfg = RunConfig(
        model=cfg, shape=ShapeConfig("ex", 64, 8, "train"),
        optimizer=OptimizerConfig(kind=kind, armijo=ArmijoConfig(),
                                  compressor=Compressor(gamma=gamma,
                                                        min_compress_size=64),
                                  eta=0.05, transport=transport,
                                  gossip=gossip, overlap=overlap,
                                  downlink=downlink,
                                  downlink_gamma=GammaControllerConfig(
                                      gamma0=downlink_gamma),
                                  faults=faults))
    # links per worker uplink: the gossip worker sends its payload to each
    # of `degree` neighbors; gather/pmean transports send to the W-1 others
    if kind in ("csgd_asss", "nonadaptive") and transport == "gossip":
        n_links = build_topology(gossip.topology, 4).degree
    else:
        n_links = 4 - 1
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=8)
    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        st = init_opt_state(params, run_cfg, 4,
                            stacked_mask=model.stacked_mask(params))
        st = jax.device_put(st, opt_state_shardings(st, params, mesh,
                                                    run_cfg))
        step_fn = None
        for i in range(steps):
            batch = pipe.batch(i)
            batch = jax.device_put(batch, jax.tree.map(
                lambda _: NamedSharding(mesh, P("data")), batch))
            if step_fn is None:
                step_fn = build_train_step(model, run_cfg, mesh)(params, batch)
            params, st, m = step_fn(params, st, batch)
            if i % 5 == 0 or i == steps - 1:
                wire = float(m["wire_bytes"])
                stale = (f" staleness={float(m['staleness']):.0f}"
                         if "staleness" in m else "")
                down = (f" down/link={float(m['downlink_wire_bytes']):.3e}"
                        if "downlink_wire_bytes" in m else "")
                hostile = (f" quar={float(m['rows_quarantined']):.0f}"
                           f" skips={float(m['steps_skipped']):.0f}"
                           if faults.enabled else "")
                print(f"  [{kind:9s}] step {i:3d} loss={float(m['loss']):.4f}"
                      f" alpha={float(m['alpha']):.4f}"
                      f" up/link={wire:.3e}"
                      f" uplink={n_links * wire:.3e}{down}"
                      f" backlog={float(m['ef_backlog']):.3f}"
                      f" cos={float(m['ef_cosine']):.3f}{stale}{hostile}")
    return float(m["wire_bytes"])


def run_federated(n_clients: int, clients_per_round: int,
                  aggregation: str, steps=15, gamma=0.05):
    """Non-IID cohort (DESIGN.md §13): W=4 dp workers vmap n_clients/4
    simulated clients each; one all_gather + one psum per round."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("yi-34b")
    model = build_model(cfg)
    run_cfg = RunConfig(
        model=cfg, shape=ShapeConfig("ex", 64, n_clients, "train"),
        optimizer=OptimizerConfig(
            kind="csgd_asss", armijo=ArmijoConfig(),
            compressor=Compressor(gamma=gamma, min_compress_size=64),
            eta=0.05,
            federated=FederatedConfig(
                n_clients=n_clients, clients_per_round=clients_per_round,
                aggregation=aggregation, dirichlet_alpha=0.3)))
    fed = run_cfg.optimizer.federated
    # client c IS shard c of the deterministic stream, Dirichlet-tilted
    cpipes = [TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                            global_batch=n_clients, seed=fed.seed,
                            n_shards=n_clients, shard=c,
                            dirichlet_alpha=fed.dirichlet_alpha)
              for c in range(n_clients)]
    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        st = init_opt_state(params, run_cfg, 4)
        st = jax.device_put(st, opt_state_shardings(st, params, mesh,
                                                    run_cfg))
        step_fn = None
        for i in range(steps):
            rows = [p.batch_with_aux(i, cfg) for p in cpipes]
            batch = {k: jnp.stack([r[k] for r in rows]) for k in rows[0]}
            batch["participation"] = participation_mask(
                n_clients, i, seed=fed.seed, mode=fed.sampling,
                clients_per_round=clients_per_round)
            batch = {k: jax.device_put(v, NamedSharding(
                mesh, P() if k == "participation" else P("data")))
                for k, v in batch.items()}
            if step_fn is None:
                step_fn = build_train_step(model, run_cfg, mesh)(params,
                                                                 batch)
            params, st, m = step_fn(params, st, batch)
            if i % 5 == 0 or i == steps - 1:
                print(f"  [{aggregation:7s}] round {i:3d} "
                      f"loss={float(m['loss']):.4f} "
                      f"participants={float(m['participants']):.0f} "
                      f"gamma={float(m['gamma']):.4f} "
                      f"wire_bytes={float(m['wire_bytes']):.3e} "
                      f"eff={float(m['effective_wire_bytes']):.3e}")
    return float(m["loss"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--transport", default="bucketed",
                    choices=list(transport_names()),
                    help="compressed-exchange schedule for the DCSGD run")
    ap.add_argument("--topology", default="ring",
                    choices=sorted(TOPOLOGIES),
                    help="gossip mixing graph (transport=gossip)")
    ap.add_argument("--consensus-lr", type=float, default=1.0,
                    help="AdaGossip consensus step numerator")
    ap.add_argument("--overlap-chunks", type=int,
                    default=OverlapConfig.n_chunks,
                    help="ring sections per gather axis "
                         "(transport=overlap, DESIGN.md §14)")
    ap.add_argument("--overlap-delay", type=int,
                    default=OverlapConfig.delay, choices=[0, 1],
                    help="1: ship the previous step's payload (overlapped,"
                         " one-step-stale aggregate); 0: bit-exact "
                         "bucketed drop-in")
    ap.add_argument("--downlink", default="dense",
                    choices=["dense", "compressed"],
                    help="aggregate return direction (DESIGN.md §15): "
                         "compressed = server-side EF re-compression "
                         "through the same wire format, no extra "
                         "collective")
    ap.add_argument("--downlink-gamma", type=float, default=0.0,
                    help="downlink compression level (0 = uplink gamma)")
    ap.add_argument("--n-clients", type=int, default=0,
                    help="> 0: federated cohort demo (DESIGN.md §13) — "
                         "support vs mean aggregation on non-IID shards")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="participating clients per round (0: all)")
    ap.add_argument("--fault-demo", action="store_true",
                    help="hostile-wire demo (DESIGN.md §16): inject a "
                         "seeded 5-step fault burst into worker 0's "
                         "gathered rows and watch the quarantine/breaker "
                         "columns")
    ap.add_argument("--steps", type=int, default=15)
    args = ap.parse_args()

    if args.n_clients:
        k = args.clients_per_round or args.n_clients
        print(f"== federated cohort: {args.n_clients} non-IID clients, "
              f"{k}/round, support-weighted aggregation ==")
        loss_s = run_federated(args.n_clients, args.clients_per_round,
                               "support", steps=args.steps)
        print("== same cohort, dense zero-averaged mean ==")
        loss_m = run_federated(args.n_clients, args.clients_per_round,
                               "mean", steps=args.steps)
        print(f"\nfinal loss: support={loss_s:.4f} mean={loss_m:.4f} "
              f"(mean averages absent coordinates' zeros)")
        return
    if args.fault_demo:
        burst = FaultConfig(seed=7, p_bitflip=0.2, p_count=0.2,
                            p_nonfinite=0.4, worker=0,
                            start_step=5, n_steps=5)
        print("== DCSGD-ASSS under a 5-step hostile-wire burst on worker "
              "0 (steps 5-9; quarantine + breaker armed) ==")
        run("csgd_asss", steps=args.steps, transport=args.transport,
            faults=burst)
        return
    gossip = GossipConfig(topology=args.topology,
                          consensus_lr=args.consensus_lr)
    overlap = OverlapConfig(n_chunks=args.overlap_chunks,
                            delay=args.overlap_delay)

    mode = "compressed, per-worker Armijo"
    if args.transport == "gossip":
        mode += f", serverless {args.topology} gossip"
    elif args.transport == "overlap":
        mode += (f", chunked-ring overlap ({args.overlap_chunks} chunks, "
                 f"delay {args.overlap_delay})")
    if args.downlink == "compressed":
        mode += ", compressed downlink (server-side EF)"
    print(f"== DCSGD-ASSS ({mode}) ==")
    wire_c = run("csgd_asss", steps=args.steps, transport=args.transport,
                 gossip=gossip, overlap=overlap, downlink=args.downlink,
                 downlink_gamma=args.downlink_gamma)
    print("== dense SGD baseline (uncompressed all-reduce) ==")
    wire_d = run("dense", steps=args.steps)
    print(f"\ncommunication saving: {wire_d / wire_c:.1f}x "
          f"({wire_c:.2e} vs {wire_d:.2e} bytes/link/step)")


if __name__ == "__main__":
    main()
