"""Quickstart: train a small transformer LM with CSGD-ASSS (Algorithm 2).

    PYTHONPATH=src python examples/quickstart.py

Covers the whole public API in ~40 lines: config -> model -> data ->
compressed adaptive optimizer -> train loop.
"""
import jax

from repro.configs import get_smoke_config
from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.data.synthetic import TokenPipeline
from repro.models import build_model


def main():
    cfg = get_smoke_config("qwen1.5-4b")       # any of the 10 archs works
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    opt = csgd_asss(CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3,   # paper: a = 3*sigma
                            omega=1.2, rho=0.8, alpha0=0.1),
        compressor=Compressor(gamma=0.01),            # 1% top_k + feedback
    ))
    state = opt.init(params)

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=128,
                         global_batch=8)

    @jax.jit
    def train_step(params, state, batch):
        return opt.step(lambda p: model.loss(p, batch)[0], params, state)

    for step in range(60):
        params, state, aux = train_step(params, state, pipe.batch(step))
        if step % 10 == 0:
            print(f"step {step:3d}  loss={float(aux.loss):.4f}  "
                  f"alpha={float(aux.alpha):.4f}  "
                  f"armijo_evals={int(aux.n_evals)}")
    print("done — adaptive step size found without any tuning.")


if __name__ == "__main__":
    main()
