"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps
with DCSGD-ASSS (deliverable (b)).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Thin wrapper over the production launcher (repro.launch.train) with the
paper's hyperparameters at 1% compression.  On this CPU container a step
takes a few seconds; pass --steps to trim.
"""
import subprocess
import sys
import os

STEPS = "300"
for i, a in enumerate(sys.argv):
    if a == "--steps":
        STEPS = sys.argv[i + 1]

repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(repo, "src")
cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "paper-lm-100m",
       "--steps", STEPS,
       "--seq-len", "128",
       "--global-batch", "8",
       "--mesh", "1x1",
       "--opt", "csgd_asss",
       "--gamma", "0.01",
       "--log-every", "10",
       "--ckpt-dir", os.path.join(repo, "results", "ckpt_100m"),
       "--ckpt-every", "100",
       "--out", os.path.join(repo, "results", "train_100m_log.json")]
print(" ".join(cmd))
sys.exit(subprocess.call(cmd, env=env, cwd=repo))
